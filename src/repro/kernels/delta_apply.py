"""delta_apply: the paper's REDOOPERATION hot loop as a Pallas TPU kernel.

Recovery redo applies a batch of logged record deltas to state pages after
the DPT/pLSN tests decided which ops actually re-execute (Algorithm 5 line
14).  For the training state store, records are fixed-width fp32 chunks and
pages are arrays of slots — so redo is a masked batched scatter:

    pages[page_idx[u], slot[u], :] = value[u]        where mask[u]

The wrapper (ops.apply_deltas) groups updates by destination page (sort +
pad to a per-page budget) so the kernel's grid walks pages: each page tile is
resident in VMEM exactly once while all its updates stream through — the
TPU-native analogue of "fetch the page once, apply every log record for it"
(the same IO-locality insight the paper's prefetch/DPT machinery serves).

mode='assign' replays after-images (idempotent, any order within a page once
LSN-sorted); mode='add' merges additive deltas (gradient-style logs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _delta_kernel(vals_ref, slot_ref, mask_ref, page_in_ref, page_out_ref, *,
                  max_upd: int, additive: bool):
    page = page_in_ref[0]                         # (slots, width) f32
    vals = vals_ref[0]                            # (max_upd, width)
    slots = slot_ref[0]                           # (max_upd,) int32
    mask = mask_ref[0]                            # (max_upd,) bool

    def body(u, pg):
        slot = slots[u]
        ok = mask[u]
        cur = jax.lax.dynamic_slice_in_dim(pg, slot, 1, axis=0)
        new = vals[u][None, :]
        if additive:
            new = cur + new
        new = jnp.where(ok, new, cur)
        return jax.lax.dynamic_update_slice_in_dim(pg, new, slot, axis=0)

    page_out_ref[0] = jax.lax.fori_loop(0, max_upd, body, page)


def delta_apply(pages, vals, slot_idx, mask, *, additive: bool = False,
                interpret: bool = False):
    """pages: (n_pages, slots, width) f32 — per-page update batches:
    vals: (n_pages, max_upd, width); slot_idx: (n_pages, max_upd) int32;
    mask: (n_pages, max_upd) bool.  Returns updated pages."""
    n_pages, slots, width = pages.shape
    max_upd = vals.shape[1]
    kernel = functools.partial(_delta_kernel, max_upd=max_upd,
                               additive=additive)
    return pl.pallas_call(
        kernel,
        grid=(n_pages,),
        in_specs=[
            pl.BlockSpec((1, max_upd, width), lambda p: (p, 0, 0)),
            pl.BlockSpec((1, max_upd), lambda p: (p, 0)),
            pl.BlockSpec((1, max_upd), lambda p: (p, 0)),
            pl.BlockSpec((1, slots, width), lambda p: (p, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, slots, width), lambda p: (p, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(pages.shape, pages.dtype),
        interpret=interpret,
    )(vals, slot_idx, mask, pages)
