"""Flash attention Pallas TPU kernel (causal, GQA).

Online-softmax attention tiled for VMEM: the grid is
(batch, q_heads, q_blocks, kv_blocks); the last grid dim is sequential on
TPU, so the running (max, denom, accumulator) live in VMEM scratch across
kv-block steps and the output tile is finalized at the last kv step.

Block shapes are MXU-aligned (q_block x head_dim, head_dim multiples of 128
preferred; 64 works at half MXU utilization).  The kv-block index map drives
GQA: q head h reads kv head h // (H // KV).

Causal blocks strictly above the diagonal are skipped with @pl.when — the
kernel does no work for them (the jnp reference computes-and-masks instead;
that difference is the kernel's win besides memory locality).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_Q_BLOCK = 128
DEFAULT_KV_BLOCK = 128
NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, scale: float, q_block: int, kv_block: int,
                  kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (not causal) or (ki * kv_block <= qi * q_block + q_block - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)              # (Cq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (Ck, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                     # (Cq, Ck)
        if causal:
            qpos = qi * q_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 0)
            kpos = ki * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    q_block: int = DEFAULT_Q_BLOCK,
                    kv_block: int = DEFAULT_KV_BLOCK,
                    interpret: bool = False):
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    assert Sq % q_block == 0 and Skv % kv_block == 0
    nq, nk = Sq // q_block, Skv // kv_block
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=scale, q_block=q_block,
        kv_block=kv_block, kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_block, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, hd), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
