"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes as traced jax ops, validating logic against the oracles in ref.py.
On TPU they compile to Mosaic.  ``use_interpret()`` picks automatically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .delta_apply import delta_apply as _delta_apply
from .flash_attention import flash_attention as _flash
from .ssd_scan import ssd_scan as _ssd
from .wkv6 import wkv6 as _wkv6


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "kv_block"))
def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 128,
                    kv_block: int = 128):
    return _flash(q, k, v, causal=causal, q_block=q_block, kv_block=kv_block,
                  interpret=use_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, logw, u, *, chunk: int = 64):
    return _wkv6(r, k, v, logw, u, chunk=chunk, interpret=use_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, B_in, C_in, A, *, chunk: int = 128):
    return _ssd(x, dt, B_in, C_in, A, chunk=chunk, interpret=use_interpret())


@functools.partial(jax.jit, static_argnames=("additive",))
def delta_apply(pages, vals, slot_idx, mask, *, additive: bool = False):
    return _delta_apply(pages, vals, slot_idx, mask, additive=additive,
                        interpret=use_interpret())


def group_updates_by_page(page_idx: np.ndarray, n_pages: int,
                          vals: np.ndarray, slots: np.ndarray,
                          apply_mask: np.ndarray, max_upd: int | None = None):
    """Host-side packer: (flat update stream) -> per-page dense batches for
    the delta_apply kernel.  Preserves log order within each page (so
    last-writer-wins assign semantics match LSN order)."""
    order = np.argsort(page_idx, kind="stable")
    width = vals.shape[-1]
    counts = np.bincount(page_idx, minlength=n_pages)
    m = int(counts.max()) if counts.size else 0
    max_upd = max_upd or max(m, 1)
    v = np.zeros((n_pages, max_upd, width), vals.dtype)
    s = np.zeros((n_pages, max_upd), np.int32)
    msk = np.zeros((n_pages, max_upd), bool)
    fill = np.zeros(n_pages, np.int32)
    for u in order:
        p = page_idx[u]
        j = fill[p]
        if j >= max_upd:
            raise ValueError(f"page {p} exceeds max_upd={max_upd}")
        v[p, j] = vals[u]
        s[p, j] = slots[u]
        msk[p, j] = apply_mask[u]
        fill[p] = j + 1
    return v, s, msk
