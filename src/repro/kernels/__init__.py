from .ops import (delta_apply, flash_attention, group_updates_by_page,
                  ssd_scan, use_interpret, wkv6)
