"""RWKV-6 WKV chunked Pallas TPU kernel.

Recurrence per head (key-dim i, value-dim j):
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (w_t data-dependent, per i)

Chunked form computed entirely in VMEM per (batch, head, chunk):
  c_t    = cumsum_t log w            (C, hd)  — within-chunk log decay
  inter  = (r ⊙ exp(c - logw)) @ S   — contribution of the carried state
  intra  = A @ v with A[t,s] = Σ_i r_t[i] k_s[i] e^{c_{t-1,i} - c_{s,i}}
           (s < t; diagonal uses the u bonus) — the (C,C,hd) pairwise tensor
           lives only in VMEM, which is why the chunked form is a *kernel*:
           materializing it in HBM for the whole sequence is impossible.
  S'     = diag(e^{c_C}) S + (k ⊙ e^{c_C - c})^T @ v

The grid's last dim walks chunks sequentially; S is carried in VMEM scratch.
Chunk=32..128 keeps the pairwise tile ≤ (128,128,64) f32 = 4 MiB in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_ref, *,
                chunk: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(jnp.float32)          # (C, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)        # log decay, negative
    u = u_ref[0].astype(jnp.float32)             # (hd,)
    S = s_ref[...]                                # (hd_k, hd_v)

    c = jnp.cumsum(lw, axis=0)                   # (C, hd)
    c_prev = c - lw                              # c_{t-1}

    # inter-chunk: y_inter[t] = (r_t * exp(c_{t-1})) @ S
    r_decayed = r * jnp.exp(c_prev)
    y_inter = jax.lax.dot_general(r_decayed, S, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # intra-chunk pairwise: A[t,s] = sum_i r_t k_s exp(c_{t-1} - c_s), s<t
    diff = c_prev[:, None, :] - c[None, :, :]    # (C, C, hd)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    pair = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    A = jnp.einsum("ti,si,tsi->ts", r, k, pair)
    A_diag = jnp.sum(r * k * u[None, :], axis=1)  # bonus on the diagonal
    A = A + jnp.diag(A_diag)
    y_intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_inter + y_intra).astype(y_ref.dtype)

    # state update: S' = diag(e^{c_C}) S + (k * e^{c_C - c})^T @ v
    c_total = c[-1]                               # (hd,)
    k_decayed = k * jnp.exp(c_total[None, :] - c)
    s_ref[...] = (jnp.exp(c_total)[:, None] * S
                  + jax.lax.dot_general(k_decayed, v,
                                        (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))


def wkv6(r, k, v, logw, u, *, chunk: int = DEFAULT_CHUNK,
         interpret: bool = False):
    """r,k,v,logw: (B, H, T, hd); u: (H, hd) -> y (B, H, T, hd)."""
    B, H, T, hd = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nt = T // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    tile = pl.BlockSpec((1, 1, chunk, hd), lambda b, h, t: (b, h, t, 0))
    return pl.pallas_call(
        kernel,
        grid=(B, H, nt),
        in_specs=[tile, tile, tile,
                  tile,
                  pl.BlockSpec((1, hd), lambda b, h, t: (h, 0))],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
