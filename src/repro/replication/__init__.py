"""Logical log-shipping replication (Deuteronomy-style TC/DC unbundling).

The PID-free logical log is the transport: one primary's stable log stream
maintains any number of standby DCs, each with its own physical layout.

Public surface:
  LogShipper / ShipBatch      cursor-based stable-log streaming
  Replica                     continuous committed-only logical redo; local
                              crash recovery via Strategy.LOG1/LOG2
  ReplicaSet / ReadResult     staleness-bounded read routing + failover
  promote                     standby -> writable primary
"""
from .failover import promote
from .replica import (REPL_KEY, REPL_TABLE, Replica, pack_watermark,
                      unpack_watermark)
from .router import ReadResult, ReplicaSet
from .shipper import SHIPPED_KINDS, LogShipper, ShipBatch

__all__ = [
    "LogShipper", "ShipBatch", "SHIPPED_KINDS", "Replica", "REPL_TABLE",
    "REPL_KEY", "pack_watermark", "unpack_watermark", "ReplicaSet",
    "ReadResult", "promote",
]
