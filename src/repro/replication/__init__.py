"""Logical log-shipping replication (Deuteronomy-style TC/DC unbundling).

The PID-free logical log is the transport: one primary's stable log stream
maintains any number of standby DCs, each with its own physical layout.

Public surface:
  LogShipper / ShipBatch      cursor-based stable-log streaming
  ApplyEngine                 shared shipped-stream semantics (gap / overlap
                              / duplicate handling, commit-granular buffers)
  Replica                     serial continuous committed-only logical redo;
                              local crash recovery via Strategy.LOG1/LOG2
  ShardedApplier              key-range parallel apply: per-shard queues and
                              sub-transactions, epoch-barrier watermark
  hash_partitioner /          (table, key) -> shard maps for ShardedApplier
  range_partitioner
  ReplicaSet / ReadResult     staleness-bounded read routing + failover
  promote                     standby -> writable primary
"""
from ..archive import SnapshotRequired
from .failover import promote
from .parallel import (RangePartitioner, ShardedApplier, ShardState,
                       hash_partitioner, range_partitioner)
from .replica import (REPL_KEY, REPL_TABLE, ApplyEngine, Replica,
                      pack_watermark, unpack_watermark)
from .router import RangeReadResult, ReadResult, ReplicaSet
from .shipper import SHIPPED_KINDS, LogShipper, ShipBatch

__all__ = [
    "LogShipper", "ShipBatch", "SHIPPED_KINDS", "ApplyEngine", "Replica",
    "ShardedApplier", "ShardState", "hash_partitioner", "range_partitioner",
    "RangePartitioner", "REPL_TABLE", "REPL_KEY", "pack_watermark",
    "unpack_watermark", "ReplicaSet", "ReadResult", "RangeReadResult",
    "promote", "SnapshotRequired",
]
