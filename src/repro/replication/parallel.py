"""Key-range parallel apply for hot standbys (the ROADMAP's Wu et al.
"Fast Failure Recovery for Main-Memory DBMSs on Multicores" item).

Why sharding is legal here at all: the apply stream is *committed-only*
(``ApplyEngine`` buffers in-flight work and releases a transaction's ops
only at its commit record), and the shipped records carry absolute logical
after-images.  Below commit granularity, ops on disjoint key ranges
therefore commute — two shards may apply their slices of the stream in any
relative order and still converge, because every key's final value is
decided by the last committed after-image on that key's own shard.

Shape of the pipeline:

  shipped batch ─► ApplyEngine (gap / overlap / dup / buffering semantics,
                   shared verbatim with the serial ``Replica``)
        commit ─► dispatch: the transaction's buffered records are sliced by
                   ``partitioner(table, key)`` into per-shard apply queues
         pump  ─► each shard applies its queued slices in commit-LSN order,
                   one local sub-transaction per (source txn, shard)
       barrier ─► every ``epoch_txns`` dispatched commits (and at end of
                   stream): all shards drain through the newest dispatched
                   commit LSN, then ONE local transaction commits the durable
                   ``(applied, resume)`` watermark row

The durable watermark moves only at barriers, so a standby crash at any
point lands local recovery on a single consistent resume point: re-shipping
from ``resume`` re-delivers the whole partial epoch, and re-applying slices
that had already landed is idempotent (absolute after-images).  Between
barriers, read-your-writes routing uses per-shard *volatile* watermarks —
a shard whose queue is empty has applied every dispatched commit that
touches it, so it can serve tokens the conservative min-over-shards barrier
cannot yet.

What the epoch batching buys over the serial path (and what the benchmark
measures): one watermark-row read-modify-write and one background page-flush
budget per *epoch* instead of per *source transaction*, while per-shard
queues expose the dispatch parallelism a multicore applier would exploit.
"""
from __future__ import annotations

import bisect
import time
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional, Union

from ..core.dc import make_key, table_range
from ..core.records import LSN, NULL_LSN, UpdateRec
from ..obs import metrics as _metrics
from ..obs.flightrec import FLIGHT as _FLIGHT
from ..obs.flightrec import auto_dump as _flight_dump
from .replica import (REPL_KEY, REPL_TABLE, _C_APPLIED_OPS, _C_APPLIED_TXNS,
                      Replica, pack_watermark)

Partitioner = Callable[[str, bytes], int]


def hash_partitioner(n_shards: int) -> Partitioner:
    """Uniform hash partitioning of (table, key).  crc32, not ``hash()``:
    the shard map must be stable across processes so a recovered standby
    re-applies every slice onto the same shard that first applied it."""
    def part(table: str, key: bytes) -> int:
        return zlib.crc32(make_key(table, key)) % n_shards
    return part


class RangePartitioner:
    """Range partitioning over composite (table, key) order: each boundary
    is the first key of the next shard, so shard i serves
    ``boundaries[i-1] <= key < boundaries[i]`` and there are
    ``len(boundaries) + 1`` shards.  Boundaries must be sorted.

    Unlike a hash map, contiguous key ranges land on contiguous shards, so
    this partitioner can also *enumerate* the shards a scan range spans —
    which is what lets a ranged read over a sharded standby take the min
    volatile watermark across only the spanned shards instead of all of
    them (``ShardedApplier.watermark_for_range``)."""

    def __init__(self, boundaries: list[tuple[str, bytes]]):
        self.splits = [make_key(t, k) for t, k in boundaries]
        if self.splits != sorted(self.splits):
            raise ValueError("range_partitioner boundaries must be sorted")
        self.n_shards = len(self.splits) + 1

    def __call__(self, table: str, key: bytes) -> int:
        return bisect.bisect_right(self.splits, make_key(table, key))

    def shards_for_range(self, lo_comp: bytes,
                         hi_comp: Optional[bytes]) -> range:
        """Shard indices the composite range [lo_comp, hi_comp) can touch
        (hi None = unbounded above)."""
        i0 = bisect.bisect_right(self.splits, lo_comp)
        i1 = len(self.splits) if hi_comp is None \
            else bisect.bisect_left(self.splits, hi_comp)
        return range(i0, i1 + 1)


def range_partitioner(boundaries: list[tuple[str, bytes]]) -> Partitioner:
    return RangePartitioner(boundaries)


@dataclass
class ShardState:
    """One key range's slice of the apply pipeline."""
    idx: int
    # in-flight slices: source txn -> its records for this range (LSN order)
    pending: dict[int, list[UpdateRec]] = field(default_factory=dict)
    # committed, not yet applied:
    # (commit_lsn, source txn, records, flush stamp, batch-receive time) —
    # the last two ride along for commit-to-visible attribution (stamp may
    # be None when the primary stamp was unavailable)
    queue: deque = field(default_factory=deque)
    dispatched_ops: int = 0
    applied_ops: int = 0
    applied_subtxns: int = 0


class ShardedApplier(Replica):
    """A ``Replica`` whose redo is sharded by key range.

    Same durable contract as the serial path — a single ``(applied, resume)``
    watermark row committed atomically with the data, local crash recovery
    via the paper's own machinery, idempotent re-apply after re-subscribe —
    but the watermark advances at epoch barriers instead of per source
    transaction, and between barriers each shard exposes its own volatile
    watermark for read routing.
    """

    def __init__(self, replica_id: str, *, n_shards: int = 4,
                 partitioner: Union[str, Partitioner] = "hash",
                 epoch_txns: int = 32, auto_pump: bool = True, **db_kwargs):
        """``partitioner``: "hash" (uniform over (table, key)) or a callable
        ``(table, key) -> shard index`` such as ``range_partitioner(...)``;
        ``epoch_txns``: dispatched source commits per durable barrier;
        ``auto_pump``: apply dispatched slices at the end of every batch
        (disable to drive ``pump``/``barrier`` by hand, e.g. in tests that
        stage per-shard progress)."""
        super().__init__(replica_id, **db_kwargs)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if epoch_txns < 1:
            raise ValueError(f"epoch_txns must be >= 1, got {epoch_txns}")
        self.n_shards = n_shards
        self.partition = hash_partitioner(n_shards) \
            if partitioner == "hash" else partitioner
        if not callable(self.partition):
            raise ValueError(f"unknown partitioner {partitioner!r}: "
                             "pass 'hash' or a callable (table, key) -> int")
        self.epoch_txns = epoch_txns
        self.auto_pump = auto_pump
        self.shards = [ShardState(i) for i in range(n_shards)]
        self._touched: dict[int, set[int]] = {}   # src txn -> shard indices
        self._dispatched_lsn: LSN = NULL_LSN      # newest dispatched commit
        self._since_barrier = 0
        self.barriers = 0
        # per-shard commit-to-visible handles (visible = the shard slice's
        # local commit; the durable barrier lags on purpose)
        self._h_shard_c2v = [
            _metrics.histogram("repl.commit_to_visible_ms",
                               replica=replica_id, shard=i)
            for i in range(n_shards)]
        self._h_shard_queue = [
            _metrics.histogram("repl.c2v.queue_wait_ms",
                               replica=replica_id, shard=i)
            for i in range(n_shards)]

    # --------------------------------------------------------- engine hooks
    def _shard_of(self, table: str, key: bytes) -> int:
        idx = self.partition(table, key)
        if not 0 <= idx < self.n_shards:
            raise ValueError(f"partitioner mapped ({table!r}, {key!r}) to "
                             f"shard {idx}, outside 0..{self.n_shards - 1}")
        return idx

    def _buffer(self, rec: UpdateRec) -> None:
        idx = self._shard_of(rec.table, rec.key)
        self.shards[idx].pending.setdefault(rec.txn, []).append(rec)
        self._touched.setdefault(rec.txn, set()).add(idx)

    def _discard(self, txn: int) -> None:
        for idx in self._touched.pop(txn, ()):
            self.shards[idx].pending.pop(txn, None)

    def _commit(self, txn: int, commit_lsn: LSN) -> int:
        # committed: irrevocably not in-flight.  Even if the epoch barrier
        # below fails mid-apply, the txn's slices stay queued (committed
        # work to retry), and it must not pin resume_floor or appear in
        # take_losers as if it could still abort.
        self._first_lsn.pop(txn, None)
        n = 0
        stamp = self._batch_stamps.get(commit_lsn)
        recv = self._batch_recv
        for idx in sorted(self._touched.pop(txn, ())):
            shard = self.shards[idx]
            ops = shard.pending.pop(txn)
            shard.queue.append((commit_lsn, txn, ops, stamp, recv))
            shard.dispatched_ops += len(ops)
            n += len(ops)
        if commit_lsn > self._dispatched_lsn:
            # first delivery; a commit re-delivered after a failed barrier
            # dispatches nothing (slices are still queued) and must not
            # bump the counters again — only retry the barrier below
            self._dispatched_lsn = commit_lsn
            self._since_barrier += 1
            self.applied_txns += 1
            _C_APPLIED_TXNS.inc()
        if self._since_barrier >= self.epoch_txns:
            self.barrier()
        return n

    def apply_batch(self, batch) -> int:
        n = super().apply_batch(batch)
        if self.auto_pump:
            self.pump()
            if not batch.has_more and self._since_barrier:
                self.barrier()      # end of stream closes the open epoch
        self.publish_metrics()
        return n

    # ------------------------------------------------------- pump / barrier
    def pump(self, shard: Optional[int] = None,
             upto_lsn: Optional[LSN] = None) -> int:
        """Apply queued committed slices in commit-LSN order, one local
        sub-transaction per (source txn, shard); returns ops applied.
        ``shard``/``upto_lsn`` restrict the work (tests, staged progress)."""
        targets = self.shards if shard is None else [self.shards[shard]]
        n = 0
        for s in targets:
            while s.queue and (upto_lsn is None or s.queue[0][0] <= upto_lsn):
                commit_lsn, src_txn, ops, stamp, recv = s.queue[0]
                self._apply_slice(s, ops, stamp=stamp, recv=recv)
                s.queue.popleft()
                n += len(ops)
        return n

    def _apply_slice(self, s: ShardState, ops: list[UpdateRec], *,
                     stamp: Optional[float] = None,
                     recv: float = 0.0) -> None:
        t_apply0 = time.perf_counter()
        _FLIGHT.record("shard.apply", s.idx, len(ops))
        txn = self.db.tc.begin()
        try:
            # same leaf-resident batched engine as the serial path — a
            # shard's slice is committed absolute after-images in source
            # LSN order, exactly what apply_shipped_batch reorders safely
            # reprolint: allow(sorted-stream) — a shard slice arrives in source LSN order by construction (the router drains per-shard queues in ship order)
            self.db.tc.apply_shipped_batch(txn, ops)
            self.db.note_updates(len(ops))
        # reprolint: allow(loud-corruption) — aborts the partial slice and dumps the black box, then re-raises unconditionally; the durable watermark re-ships it after recovery
        except Exception:
            # undo the partial slice; the queue still holds it, and the
            # durable watermark (last barrier) re-ships it after recovery
            self.db.tc.abort(txn)
            _flight_dump("shard.apply_failed")
            raise
        self.db.tc.commit(txn)
        s.applied_subtxns += 1
        s.applied_ops += len(ops)
        self.applied_ops += len(ops)
        _C_APPLIED_OPS.inc(len(ops))
        if stamp is not None:
            t_done = time.perf_counter()
            self._h_shard_c2v[s.idx].observe(
                round((t_done - stamp) * 1e3, 6))
            self._h_shard_queue[s.idx].observe(
                round(max(0.0, t_apply0 - recv) * 1e3, 6))
            self._h_ship_wait.observe(
                round(max(0.0, recv - stamp) * 1e3, 6))
            self._h_apply.observe(round((t_done - t_apply0) * 1e3, 6))

    def barrier(self) -> LSN:
        """Epoch barrier: drain every shard through the newest dispatched
        commit, then commit the durable ``(applied, resume)`` watermark in
        one local transaction.  Standby crash recovery therefore always
        lands on this single consistent resume point, never inside an
        epoch."""
        self.pump()
        self._since_barrier = 0
        b = self._dispatched_lsn
        if b <= self.applied_lsn:
            return self.applied_lsn
        resume = self.resume_floor(b)
        txn = self.db.tc.begin()
        self.db.tc.update(txn, REPL_TABLE, REPL_KEY, pack_watermark(b, resume))
        self.db.tc.commit(txn)
        self.db.post_commit_flush()     # page-flush budget, once per epoch
        self.applied_lsn, self.resume_lsn = b, resume
        self.barriers += 1
        return b

    def finish_apply(self) -> None:
        self.pump()

    # ---------------------------------------------------------- watermarks
    def shard_watermark(self, idx: int) -> LSN:
        """Volatile per-range watermark: every dispatched commit at or below
        it whose slice touches this shard has been applied.  Empty queue
        means the shard is current through the newest dispatched commit;
        otherwise everything older than the queue head is in (commits are
        dispatched in LSN order)."""
        s = self.shards[idx]
        base = self._dispatched_lsn if not s.queue else s.queue[0][0] - 1
        return max(base, self.applied_lsn)

    def catchup_lsn(self) -> LSN:
        return min(self.shard_watermark(i) for i in range(self.n_shards))

    def watermark_for(self, table: str, key: bytes) -> LSN:
        """Read-your-writes eligibility: the serving shard's volatile
        watermark, falling back to the conservative min-over-shards barrier
        when the key does not map cleanly onto a shard."""
        try:
            idx = self._shard_of(table, key)
        # reprolint: allow(loud-corruption) — LookupError here is the partitioner's documented "no clean shard" signal, answered with the conservative min-over-shards barrier; media's BackendMissingError cannot reach a shard-map probe
        except LookupError:
            # "does not map cleanly" only (e.g. a table-map partitioner that
            # has no entry for this key) — anything else, including the
            # out-of-range ValueError, is a partitioner bug and fails as
            # loudly here as it does on the apply path
            return self.catchup_lsn()
        return self.shard_watermark(idx)

    def watermark_for_range(self, table: str, lo: Optional[bytes] = None,
                            hi: Optional[bytes] = None) -> LSN:
        """Ranged staleness token: the min volatile watermark across the
        shards [lo, hi) spans — the ROADMAP rule that a scan over a sharded
        standby is only as fresh as its laggiest spanned shard.  Range
        partitioners enumerate the spanned shards; opaque maps (hash) smear
        any range over every shard, so they fall back to the global min."""
        part = self.partition
        if hasattr(part, "shards_for_range"):
            lo_c, hi_c = table_range(table, lo, hi)
            idxs = [i for i in part.shards_for_range(lo_c, hi_c)
                    if 0 <= i < self.n_shards]
            if idxs:
                return min(self.shard_watermark(i) for i in idxs)
        return self.catchup_lsn()

    # ------------------------------------------------------ buffered state
    @property
    def pending(self) -> dict[int, list[UpdateRec]]:
        merged: dict[int, list[UpdateRec]] = {}
        for s in self.shards:
            for txn, ops in s.pending.items():
                merged.setdefault(txn, []).extend(ops)
        return {txn: sorted(ops, key=lambda r: r.lsn)
                for txn, ops in merged.items()}

    def take_losers(self) -> dict[int, list[UpdateRec]]:
        losers = self.pending
        for s in self.shards:
            s.pending.clear()
        self._touched.clear()
        self._first_lsn.clear()
        return losers

    def _reset_volatile(self) -> None:
        super()._reset_volatile()
        for s in self.shards:
            s.pending.clear()
            s.queue.clear()
        self._touched.clear()
        self._dispatched_lsn = NULL_LSN
        self._since_barrier = 0

    # ----------------------------------------------------------- inspection
    def queued_slices(self) -> int:
        return sum(len(s.queue) for s in self.shards)

    def publish_metrics(self) -> None:
        """Refresh the live per-shard gauges: dispatched ops, dispatch
        share (ops relative to the perfectly balanced share), volatile
        watermark, and lag behind the newest dispatched commit — plus the
        overall dispatch-imbalance gauge the ROADMAP's adaptive
        re-partitioning follow-on will act on.  Runs after every applied
        batch on the auto-pump path; manual pump/barrier drivers call it
        directly."""
        total = sum(s.dispatched_ops for s in self.shards)
        fair = total / self.n_shards if total else 0.0
        newest = self._dispatched_lsn
        for s in self.shards:
            wm = self.shard_watermark(s.idx)
            labels = {"replica": self.replica_id, "shard": s.idx}
            _metrics.gauge("repl.shard.dispatched_ops",
                           **labels).set(s.dispatched_ops)
            _metrics.gauge("repl.shard.dispatch_share", **labels).set(
                round(s.dispatched_ops / fair, 4) if fair else 1.0)
            _metrics.gauge("repl.shard.watermark", **labels).set(wm)
            _metrics.gauge("repl.shard.lag", **labels).set(
                max(0, newest - wm) if newest != NULL_LSN else 0)
        _metrics.gauge("repl.dispatch_imbalance",
                       replica=self.replica_id).set(round(self.imbalance(),
                                                          4))

    def imbalance(self) -> float:
        """Dispatch skew: max over shards of dispatched ops, relative to the
        perfectly balanced share (1.0 = uniform; n_shards = one hot shard)."""
        total = sum(s.dispatched_ops for s in self.shards)
        if total == 0:
            return 1.0
        return max(s.dispatched_ops for s in self.shards) \
            / (total / self.n_shards)
