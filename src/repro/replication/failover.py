"""Failover: promote a hot standby to a writable primary.

``promote`` is deliberately shaped like the tail of crash recovery
(Section 2.1's repeat-history-then-undo), because that is exactly what a
takeover is: the stable shipped log plays the role of the surviving log.

  1. Drain — ship and apply every remaining stable record, so all
     transactions the dead primary acknowledged as committed are present.
  2. Losers — transactions still in the replica's in-flight buffer have a
     stable prefix but no commit: repeat their history through the local TC,
     then undo them logically with the *existing*
     ``TransactionalComponent.abort`` (CLR-protected).  This leaves the same
     abort trail in the new primary's log that crash recovery would, so a
     future consumer of the new primary's log sees those transactions
     resolved rather than silently vanished.  Undo is per-transaction in
     descending last-LSN order — ``recover()``'s exact discipline, and like
     it correct under the TC's logical-locking model, which excludes
     write-write interleavings between uncommitted transactions on a key.
  3. Retire the ``__repl`` watermark row — it is a position in the DEAD
     primary's LSN space, meaningless (and a phantom row for scans) on a
     database that is itself a primary now.
  4. End-of-recovery checkpoint — same reason ``recover()`` takes one:
     pages dirtied by apply carry old LSNs that would violate the
     Delta-record rLSN approximation for post-promotion Delta records.

Returns the replica's ``Database``, now writable as the new primary.
"""
from __future__ import annotations

from ..core.tc import Database
from .replica import REPL_KEY, REPL_TABLE, Replica
from .shipper import LogShipper


def promote(replica: Replica, shipper: LogShipper) -> Database:
    if replica.promoted:
        raise RuntimeError(f"replica {replica.replica_id} already promoted")

    # 1. drain the shipped tail, then whatever of it is still queued behind
    # the apply pipeline (the sharded path dispatches to per-range queues;
    # every committed slice must land before undo decides what "lost")
    shipper.drain(replica.replica_id, replica.apply_batch)
    replica.finish_apply()

    # 2. merge the in-flight loser buffers — per-shard slices on the sharded
    # path, the buffers themselves on the serial one — and repeat history
    # for ALL losers in primary-LSN order, then undo newest-first —
    # recover()'s exact discipline.  Ordering matters when losers interleave
    # on a key: undo restores original before-images, which only compose
    # back to the committed value newest-first.
    losers = replica.take_losers()
    local: dict[int, int] = {}
    for rec in sorted((r for buf in losers.values() for r in buf),
                      key=lambda r: r.lsn):
        txn = local.get(rec.txn)
        if txn is None:
            txn = local[rec.txn] = replica.db.tc.begin()
        replica.db.tc.apply_shipped(txn, rec)
    for src_txn in sorted(losers, key=lambda t: -losers[t][-1].lsn):
        replica.db.tc.abort(local[src_txn])   # logical undo, CLRs + AbortRec

    # 3. retire the old-LSN-space watermark row
    if replica.db.dc.read(REPL_TABLE, REPL_KEY) is not None:
        txn = replica.db.tc.begin()
        replica.db.tc.delete(txn, REPL_TABLE, REPL_KEY)
        replica.db.tc.commit(txn)

    # 4. end-of-recovery checkpoint; the database is now a writable primary
    replica.db.checkpoint()
    replica.promoted = True
    return replica.db
