"""Hot standby: continuous logical redo of a shipped log stream onto a DC
with its own geometry.

The replica is a full ``Database`` — own log, own B-tree (possibly a
different page size than the primary), own Delta-records and DPT — so the
paper's entire recovery machinery works *locally*: a crashed replica
recovers itself with ``Strategy.LOG1``/``LOG2`` and then re-subscribes,
rather than being re-seeded from scratch.

Apply discipline (committed-only):
  * update records buffer per source transaction (in-flight work is never
    visible to reads);
  * a commit record replays the buffered chain through the replica's own TC
    as one local transaction;
  * an abort record discards the buffer (CLRs never ship: a transaction
    either commits cleanly or ends in AbortRec, and the abort alone tells a
    buffering consumer everything).

Durable watermark: every applied commit also writes, *inside the same local
transaction*, a row in the ``__repl`` system table recording
``(applied, resume)`` in primary-LSN space:

  applied — the primary commit LSN of the last transaction applied; a
            replica can serve a read-your-writes token t iff applied >= t.
  resume  — where shipping must restart so that no in-flight transaction's
            records are missed: min over buffered transactions of their
            first record's LSN (or applied+1 when none are buffered).

Because the watermark commits atomically with the data, local crash recovery
reconstructs exactly the replication position matching the recovered state —
re-subscribing from ``resume`` re-ships some records, and commits with
LSN <= ``applied`` are dropped as duplicates (idempotent re-apply).

The stream state machine itself lives in ``ApplyEngine`` so the serial path
here and the key-range-sharded path (``parallel.ShardedApplier``) share one
set of gap / overlap / duplicate / resume semantics and differ only in where
buffered ops live and when they are applied.
"""
from __future__ import annotations

import struct
import time
from typing import Optional

from ..core.dc import make_key, split_key
from ..core.records import (LSN, NULL_LSN, AbortRec, CommitRec, LogRec,
                            UpdateRec)
from ..core.recovery import RecoveryStats, Strategy, recover
from ..core.tc import CrashImage, Database
from ..obs import metrics as _metrics
from ..obs.flightrec import FLIGHT as _FLIGHT
from ..obs.flightrec import auto_dump as _flight_dump
from .shipper import LogShipper, ShipBatch

_C_APPLIED_TXNS = _metrics.counter("repl.applied_txns")
_C_APPLIED_OPS = _metrics.counter("repl.applied_ops")

REPL_TABLE = "__repl"
REPL_KEY = b"applied"


def pack_watermark(applied: LSN, resume: LSN) -> bytes:
    return struct.pack("<QQ", applied, resume)


def unpack_watermark(raw: bytes) -> tuple[LSN, LSN]:
    return struct.unpack("<QQ", raw)


class ApplyEngine:
    """Shipped-stream state machine shared by serial and sharded apply.

    Owns everything about *stream position and transaction boundaries*:

      * gap detection — a batch that starts past the consumed position means
        records were shipped elsewhere and is rejected;
      * overlap dedup — records below the consumed position are batch
        re-deliveries (an overlapping poll, a rewound shipper cursor) and are
        skipped, never re-buffered;
      * commit dedup — a commit at or below the durable ``applied`` watermark
        was already applied (re-subscription rescan) and is dropped whole;
      * in-flight bookkeeping — which source transactions are open and the
        LSN of each one's first record, which is exactly what the durable
        ``resume`` computation needs.

    Storage of the buffered ops and their application are delegated to the
    subclass through three hooks:

      _buffer(rec)               stash one in-flight update record
      _discard(txn)              drop a buffered transaction (abort / dup)
      _commit(txn, commit_lsn)   apply a committed transaction; returns the
                                 number of ops applied
    """

    def __init__(self, replica_id: str):
        self.replica_id = replica_id
        self.applied_lsn: LSN = NULL_LSN       # durable primary commit watermark
        self.resume_lsn: LSN = 1               # durable shipping resume point
        self._ship_pos: LSN = 1                # next primary LSN expected
        self._first_lsn: dict[int, LSN] = {}   # in-flight txn -> first rec LSN
        self.applied_txns = 0
        self.applied_ops = 0
        self.dropped_dup_txns = 0
        self.skipped_dup_recs = 0
        self.promoted = False
        # commit-to-visible plumbing: the current batch's primary flush
        # stamps and the instant this engine received the batch (both
        # perf_counter; comparable within this process only)
        self._batch_stamps: dict = {}
        self._batch_recv: float = 0.0

    # ----------------------------------------------------------- ingestion
    def apply_batch(self, batch: ShipBatch) -> int:
        """Continuous redo of one shipped batch; returns ops applied.

        Rejects a batch that skips ahead of the last position this replica
        consumed: a gap means records were shipped elsewhere (e.g. the
        shipper cursor is stale after a local recovery without
        ``resubscribe``), and applying past it would silently lose the
        buffered prefix of straddling transactions.  The opposite overlap —
        a batch that starts *below* the consumed position — is benign
        re-delivery; already-consumed records are skipped so straddling
        transactions are not double-buffered."""
        self._batch_stamps = getattr(batch, "stamps", None) or {}
        self._batch_recv = time.perf_counter()
        if batch.from_lsn > self._ship_pos:
            raise RuntimeError(
                f"replica {self.replica_id}: shipped batch starts at LSN "
                f"{batch.from_lsn} but {self._ship_pos} was expected — "
                f"re-subscribe from resume_lsn={self.resume_lsn}")
        n = 0
        for rec in batch.records:
            if rec.lsn < self._ship_pos:
                self.skipped_dup_recs += 1
                continue
            n += self.apply_record(rec)
            self._ship_pos = rec.lsn + 1
        self._ship_pos = max(self._ship_pos, batch.next_lsn)
        return n

    def apply_record(self, rec: LogRec) -> int:
        if self.promoted:
            raise RuntimeError(
                f"replica {self.replica_id} was promoted; applying shipped "
                "records from the old primary would corrupt the new one")
        if isinstance(rec, UpdateRec):
            self._first_lsn.setdefault(rec.txn, rec.lsn)
            self._buffer(rec)
        elif isinstance(rec, AbortRec):
            self._first_lsn.pop(rec.txn, None)
            self._discard(rec.txn)
        elif isinstance(rec, CommitRec):
            if rec.lsn <= self.applied_lsn:
                # duplicate from a re-subscription rescan: already applied
                self._first_lsn.pop(rec.txn, None)
                self._discard(rec.txn)
                self.dropped_dup_txns += 1
                return 0
            # the hook owns the txn's in-flight -> committed transition of
            # _first_lsn: the serial path restores it when apply fails (the
            # ops go back in the buffer, the commit will be re-delivered);
            # the sharded path drops it at dispatch irrevocably — a
            # committed transaction is not a loser and must never pin the
            # resume watermark, no matter what later pump/barrier work does
            return self._commit(rec.txn, rec.lsn)
        return 0

    def resume_floor(self, commit_lsn: LSN) -> LSN:
        """Durable resume point as of ``commit_lsn``: shipping may restart
        here without missing any record of a still-in-flight transaction."""
        return min(min(self._first_lsn.values(), default=commit_lsn + 1),
                   commit_lsn + 1)

    # ------------------------------------------------------- subclass hooks
    def _buffer(self, rec: UpdateRec) -> None:
        raise NotImplementedError

    def _discard(self, txn: int) -> None:
        raise NotImplementedError

    def _commit(self, txn: int, commit_lsn: LSN) -> int:
        raise NotImplementedError

    # ------------------------------------------------------- shared surface
    @property
    def pending(self) -> dict[int, list[UpdateRec]]:
        """In-flight buffers as {source txn: [records in LSN order]} — a
        merged view for the sharded path, the buffers themselves here."""
        raise NotImplementedError

    def take_losers(self) -> dict[int, list[UpdateRec]]:
        """Hand every in-flight buffer (merged across shards, LSN-ordered)
        to the caller — failover's loser set — and forget them."""
        raise NotImplementedError

    def finish_apply(self) -> None:
        """Apply everything already ingested but not yet executed (sharded
        queues); a no-op on the serial path, which applies at ingest."""

    def catchup_lsn(self) -> LSN:
        """Highest primary commit LSN whose effects are fully applied to the
        local database — the durable watermark on the serial path, the
        min-over-shards volatile watermark on the sharded path."""
        return self.applied_lsn

    def watermark_for(self, table: str, key: bytes) -> LSN:
        """Read-your-writes eligibility for one key: the highest token this
        node can serve for it.  Serial apply is totally ordered, so this is
        the global watermark; the sharded path answers per key range."""
        return self.applied_lsn

    def watermark_for_range(self, table: str, lo: Optional[bytes] = None,
                            hi: Optional[bytes] = None) -> LSN:
        """Staleness token a ranged scan over [lo, hi) can be served under:
        a scan is only as fresh as the laggiest key range it spans, so the
        sharded path takes the min volatile watermark across the spanned
        shards; serial apply is totally ordered and answers globally."""
        return self.applied_lsn

    def lag(self, primary_log) -> int:
        """Staleness in primary-LSN units: distance from the primary's last
        *stable commit* (non-commit tail records — in-flight work, abort
        trails — cannot make a committed-only replica stale, and neither can
        a commit record sitting past the stable point: it never shipped)."""
        lag = max(0, primary_log.last_stable_commit_lsn - self.catchup_lsn())
        _metrics.gauge("repl.lag", replica=self.replica_id).set(lag)
        return lag


class Replica(ApplyEngine):
    def __init__(self, replica_id: str, *, page_size: Optional[int] = None,
                 cache_pages: int = 4096, tracker_interval: int = 100,
                 bg_flush_per_txn: int = 0, delta_mode: str = "paper",
                 seed_tables: Optional[dict[str, list]] = None):
        """``seed_tables``: table -> [(key, value)] initial load, which must
        match the primary's state at the LSN the subscription starts from."""
        super().__init__(replica_id)
        self.page_size = page_size
        self.cache_pages = cache_pages
        self.delta_mode = delta_mode
        self.tracker_interval = tracker_interval
        self.bg_flush_per_txn = bg_flush_per_txn
        self.db = Database(cache_pages=cache_pages, delta_mode=delta_mode,
                           tracker_interval=tracker_interval,
                           bg_flush_per_txn=bg_flush_per_txn,
                           page_size=page_size)
        if seed_tables:
            items = [(make_key(t, k), v)
                     for t, rows in seed_tables.items() for k, v in rows]
            self.db.dc.bulk_build(items)
            self.db.tc.checkpoint()
        else:
            self.db.bootstrap_empty()
        self._bufs: dict[int, list[UpdateRec]] = {}
        # end-to-end latency (primary flush -> locally visible) plus its
        # per-stage attribution; handles cached once, observed per commit
        self._h_c2v = _metrics.histogram("repl.commit_to_visible_ms",
                                         replica=replica_id)
        self._h_ship_wait = _metrics.histogram("repl.c2v.ship_wait_ms",
                                               replica=replica_id)
        self._h_queue_wait = _metrics.histogram("repl.c2v.queue_wait_ms",
                                                replica=replica_id)
        self._h_apply = _metrics.histogram("repl.c2v.apply_ms",
                                           replica=replica_id)

    # ------------------------------------------------------------ apply path
    def _buffer(self, rec: UpdateRec) -> None:
        self._bufs.setdefault(rec.txn, []).append(rec)

    def _discard(self, txn: int) -> None:
        self._bufs.pop(txn, None)

    def _commit(self, txn: int, commit_lsn: LSN) -> int:
        first = self._first_lsn.pop(txn, None)
        try:
            return self._apply_commit(txn, commit_lsn, self._bufs.pop(txn, []))
        # reprolint: allow(loud-corruption) — restores the in-flight buffer bookkeeping and dumps the black box, then re-raises unconditionally: nothing is swallowed
        except Exception:
            if first is not None:    # ops are back in the buffer: still
                self._first_lsn[txn] = first    # in-flight for resume/losers
            _flight_dump("replica.apply_failed")
            raise

    @property
    def pending(self) -> dict[int, list[UpdateRec]]:
        return self._bufs

    def take_losers(self) -> dict[int, list[UpdateRec]]:
        losers, self._bufs = self._bufs, {}
        self._first_lsn.clear()
        return losers

    def _apply_commit(self, src_txn: int, commit_lsn: LSN,
                      ops: list[UpdateRec]) -> int:
        t_apply0 = time.perf_counter()
        _FLIGHT.record("repl.apply", commit_lsn, len(ops))
        resume = self.resume_floor(commit_lsn)
        txn = self.db.tc.begin()
        try:
            # one sorted walk through the leaf-resident batched engine
            # (shared with recovery redo and snapshot heal-replay)
            # reprolint: allow(sorted-stream) — ops is a per-txn ship buffer appended in primary log order, and apply_shipped_batch re-sorts by (table, key, lsn) internally
            self.db.tc.apply_shipped_batch(txn, ops)
            self.db.note_updates(len(ops))       # replica-local Delta-records
            self.db.tc.update(txn, REPL_TABLE, REPL_KEY,
                              pack_watermark(commit_lsn, resume))
        # reprolint: allow(loud-corruption) — prefix-undo abort then unconditional re-raise: the failure surfaces to the shipping loop
        except Exception:
            # keep the replica committed-only consistent: logically undo the
            # partially applied prefix (before-images are on the local log),
            # put the buffer back, and surface the failure — e.g. a record
            # that fits the primary's page size but not this geometry
            self.db.tc.abort(txn)
            self._bufs[src_txn] = ops
            raise
        self.db.tc.commit(txn)
        self.db.post_commit_flush()
        self.applied_lsn, self.resume_lsn = commit_lsn, resume
        self.applied_txns += 1
        self.applied_ops += len(ops)
        _C_APPLIED_TXNS.inc()
        _C_APPLIED_OPS.inc(len(ops))
        _metrics.gauge("repl.applied_lsn",
                       replica=self.replica_id).set(commit_lsn)
        stamp = self._batch_stamps.get(commit_lsn)
        if stamp is not None:
            t_done = time.perf_counter()
            self._h_c2v.observe(round((t_done - stamp) * 1e3, 6))
            self._h_ship_wait.observe(
                round(max(0.0, self._batch_recv - stamp) * 1e3, 6))
            self._h_queue_wait.observe(
                round(max(0.0, t_apply0 - self._batch_recv) * 1e3, 6))
            self._h_apply.observe(round((t_done - t_apply0) * 1e3, 6))
        return len(ops)

    # --------------------------------------------------------------- reads
    def read(self, table: str, key: bytes) -> Optional[bytes]:
        return self.db.dc.read(table, key)

    def scan_range(self, table: str, lo: Optional[bytes] = None,
                   hi: Optional[bytes] = None) -> list[tuple[bytes, bytes]]:
        """Ranged read of [lo, hi) (None = table edge).  The replica holds
        committed state only (in-flight work buffers outside the tree), so
        the raw tree scan already has the right visibility."""
        return self.db.dc.scan_range(table, lo, hi)

    def user_state(self) -> dict[bytes, bytes]:
        """Replica state minus the ``__repl`` system table — directly
        comparable against ``committed_state_oracle``."""
        return {k: v for k, v in self.db.scan_all()
                if split_key(k)[0] != REPL_TABLE}

    # ------------------------------------------------------- crash / recovery
    def crash(self) -> CrashImage:
        return self.db.crash()

    def _reset_volatile(self) -> None:
        """Forget every buffer that does not survive a crash and rewind the
        stream position to the durable resume point."""
        self._bufs = {}
        self._first_lsn.clear()
        self._ship_pos = self.resume_lsn

    def recover_local(self, strategy: Strategy = Strategy.LOG1,
                      image: Optional[CrashImage] = None) -> RecoveryStats:
        """Crash (or take ``image``) and recover THIS replica with the
        paper's own machinery, on its own geometry, from its own
        Delta-records — then restore the replication position from the
        durable watermark row.  In-flight buffers are volatile and lost; the
        ``resume`` watermark is exactly what makes that safe."""
        image = image or self.db.crash()
        self.db, stats = recover(image, strategy,
                                 cache_pages=self.cache_pages,
                                 delta_mode=self.delta_mode,
                                 page_size=self.page_size,
                                 tracker_interval=self.tracker_interval,
                                 bg_flush_per_txn=self.bg_flush_per_txn)
        raw = self.db.dc.read(REPL_TABLE, REPL_KEY)
        self.applied_lsn, self.resume_lsn = \
            unpack_watermark(raw) if raw is not None else (NULL_LSN, 1)
        self._reset_volatile()
        return stats

    def resubscribe(self, shipper: LogShipper) -> None:
        """Point ``shipper`` at this replica's durable resume point.  Also
        rewinds the in-flight buffers: everything from ``resume_lsn`` on is
        about to be re-shipped, and keeping stale buffers would double-apply
        straddling transactions."""
        self._reset_volatile()
        shipper.subscribe(self.replica_id, self.resume_lsn)

    def catch_up(self, shipper: LogShipper, *, retry=None) -> int:
        """Drain ``shipper`` into this replica, absorbing transient backend
        outages (cold shipping cursors read through the archive's backend)
        by backing off and re-subscribing from the durable resume point —
        re-shipped records dedup through the ordinary overlap/duplicate
        machinery, so convergence to the committed oracle is unaffected by
        where the outage struck.  Bounded: after ``retry.max_attempts``
        consecutive failed rounds the last transient error propagates.
        Returns ops applied.  ``retry`` is a ``faults.RetryPolicy``
        (default-constructed when omitted)."""
        # call-time imports: replication must not pull faults/media in at
        # module load (the dependency arrow points the other way)
        from ..faults.retry import RetryPolicy
        from ..media.errors import BackendUnavailableError
        if retry is None:
            retry = RetryPolicy()
        applied = 0
        failures = 0
        while True:
            try:
                batch = shipper.poll(self.replica_id)
                applied += self.apply_batch(batch)
            except BackendUnavailableError:
                failures += 1
                if failures >= retry.max_attempts:
                    raise
                retry.backoff(failures)
                _FLIGHT.record("repl.resubscribe", failures)
                self.resubscribe(shipper)
                continue
            failures = 0
            if not batch.has_more:
                return applied

    # --------------------------------------------------------------- reseed
    def reseed_from(self, snapshot) -> None:
        """Replace this standby's entire local database with a fuzzy
        logical ``Snapshot`` (``archive.SnapshotStore``), keeping the
        replica's identity and physical configuration — the snapshot is
        geometry-free, so a 4 KiB-page standby reseeds from an 8 KiB-page
        primary unchanged.

        The durable ``(applied, resume)`` watermark is set to the snapshot
        window: ``applied = begin_lsn`` (every commit at or below begin is
        fully present; commits inside the fuzz window re-deliver and
        re-apply idempotently via absolute after-images) and ``resume =
        redo_lsn`` (covers transactions straddling the snapshot begin).
        Subscribing at ``resume_lsn`` afterwards is plain catch-up through
        the ordinary shipping path.

        This is the re-seed that failover survivors and below-horizon
        laggards take instead of being detached: new LSN space, new primary,
        same standby object."""
        self.db = Database(cache_pages=self.cache_pages,
                           delta_mode=self.delta_mode,
                           tracker_interval=self.tracker_interval,
                           bg_flush_per_txn=self.bg_flush_per_txn,
                           page_size=self.page_size)
        self.db.dc.bulk_build(list(snapshot.rows))
        self.db.tc.checkpoint()
        txn = self.db.tc.begin()
        self.db.tc.insert(txn, REPL_TABLE, REPL_KEY,
                          pack_watermark(snapshot.begin_lsn,
                                         snapshot.redo_lsn))
        self.db.tc.commit(txn)
        self.applied_lsn = snapshot.begin_lsn
        self.resume_lsn = snapshot.redo_lsn
        self.promoted = False
        self._reset_volatile()

    def reseed_from_backend(self, where, *, target_lsn=None):
        """``reseed_from`` against durable media: load the snapshot store
        from a ``MediaBackend`` (or directory path) and seed from its
        newest snapshot (<= ``target_lsn`` when given).  This is how a
        standby joins a *dead* primary's lineage — nothing of the old
        process survives but bytes on the backend, and that is enough to
        put this node at the snapshot window, ready to subscribe to
        whoever now serves the log.  Returns the snapshot used."""
        # call-time import: replication must not depend on archive/media
        # at module load (the dependency arrow points archive -> replication)
        from ..media.restore import load_media
        _backend, _archive, store = load_media(where)
        snap = store.latest() if target_lsn is None else \
            store.latest_for(target_lsn)
        if snap is None:
            raise ValueError(
                f"backend {where!r} holds no usable snapshot"
                + (f" at or below LSN {target_lsn}" if target_lsn else "")
                + " — run the archiver/snapshot store against it first")
        self.reseed_from(snap)
        return snap
