"""Hot standby: continuous logical redo of a shipped log stream onto a DC
with its own geometry.

The replica is a full ``Database`` — own log, own B-tree (possibly a
different page size than the primary), own Delta-records and DPT — so the
paper's entire recovery machinery works *locally*: a crashed replica
recovers itself with ``Strategy.LOG1``/``LOG2`` and then re-subscribes,
rather than being re-seeded from scratch.

Apply discipline (committed-only):
  * update records buffer per source transaction (in-flight work is never
    visible to reads);
  * a commit record replays the buffered chain through the replica's own TC
    as one local transaction;
  * an abort record discards the buffer (CLRs never ship: a transaction
    either commits cleanly or ends in AbortRec, and the abort alone tells a
    buffering consumer everything).

Durable watermark: every applied commit also writes, *inside the same local
transaction*, a row in the ``__repl`` system table recording
``(applied, resume)`` in primary-LSN space:

  applied — the primary commit LSN of the last transaction applied; a
            replica can serve a read-your-writes token t iff applied >= t.
  resume  — where shipping must restart so that no in-flight transaction's
            records are missed: min over buffered transactions of their
            first record's LSN (or applied+1 when none are buffered).

Because the watermark commits atomically with the data, local crash recovery
reconstructs exactly the replication position matching the recovered state —
re-subscribing from ``resume`` re-ships some records, and commits with
LSN <= ``applied`` are dropped as duplicates (idempotent re-apply).
"""
from __future__ import annotations

import struct
from typing import Optional

from ..core.dc import make_key, split_key
from ..core.records import (LSN, NULL_LSN, AbortRec, CommitRec, LogRec,
                            UpdateRec)
from ..core.recovery import RecoveryStats, Strategy, recover
from ..core.tc import CrashImage, Database
from .shipper import LogShipper, ShipBatch

REPL_TABLE = "__repl"
REPL_KEY = b"applied"


def pack_watermark(applied: LSN, resume: LSN) -> bytes:
    return struct.pack("<QQ", applied, resume)


def unpack_watermark(raw: bytes) -> tuple[LSN, LSN]:
    return struct.unpack("<QQ", raw)


class Replica:
    def __init__(self, replica_id: str, *, page_size: Optional[int] = None,
                 cache_pages: int = 4096, tracker_interval: int = 100,
                 bg_flush_per_txn: int = 0, delta_mode: str = "paper",
                 seed_tables: Optional[dict[str, list]] = None):
        """``seed_tables``: table -> [(key, value)] initial load, which must
        match the primary's state at the LSN the subscription starts from."""
        self.replica_id = replica_id
        self.page_size = page_size
        self.cache_pages = cache_pages
        self.delta_mode = delta_mode
        self.tracker_interval = tracker_interval
        self.bg_flush_per_txn = bg_flush_per_txn
        self.db = Database(cache_pages=cache_pages, delta_mode=delta_mode,
                           tracker_interval=tracker_interval,
                           bg_flush_per_txn=bg_flush_per_txn,
                           page_size=page_size)
        if seed_tables:
            items = [(make_key(t, k), v)
                     for t, rows in seed_tables.items() for k, v in rows]
            self.db.dc.bulk_build(items)
            self.db.tc.checkpoint()
        else:
            self.db.bootstrap_empty()
        self.applied_lsn: LSN = NULL_LSN       # primary commit watermark
        self.resume_lsn: LSN = 1               # durable shipping resume point
        self._ship_pos: LSN = 1                # next primary LSN expected
        self.pending: dict[int, list[UpdateRec]] = {}
        self.applied_txns = 0
        self.applied_ops = 0
        self.dropped_dup_txns = 0
        self.promoted = False

    # ------------------------------------------------------------ apply path
    def apply_batch(self, batch: ShipBatch) -> int:
        """Continuous redo of one shipped batch; returns ops applied.

        Rejects a batch that skips ahead of the last position this replica
        consumed: a gap means records were shipped elsewhere (e.g. the
        shipper cursor is stale after a local recovery without
        ``resubscribe``), and applying past it would silently lose the
        buffered prefix of straddling transactions."""
        if batch.from_lsn > self._ship_pos:
            raise RuntimeError(
                f"replica {self.replica_id}: shipped batch starts at LSN "
                f"{batch.from_lsn} but {self._ship_pos} was expected — "
                f"re-subscribe from resume_lsn={self.resume_lsn}")
        n = 0
        for rec in batch.records:
            n += self.apply_record(rec)
        self._ship_pos = max(self._ship_pos, batch.next_lsn)
        return n

    def apply_record(self, rec: LogRec) -> int:
        if self.promoted:
            raise RuntimeError(
                f"replica {self.replica_id} was promoted; applying shipped "
                "records from the old primary would corrupt the new one")
        if isinstance(rec, UpdateRec):
            self.pending.setdefault(rec.txn, []).append(rec)
        elif isinstance(rec, AbortRec):
            self.pending.pop(rec.txn, None)
        elif isinstance(rec, CommitRec):
            ops = self.pending.pop(rec.txn, [])
            if rec.lsn <= self.applied_lsn:
                # duplicate from a re-subscription rescan: already applied
                self.dropped_dup_txns += 1
                return 0
            return self._apply_commit(rec.txn, rec.lsn, ops)
        return 0

    def _apply_commit(self, src_txn: int, commit_lsn: LSN,
                      ops: list[UpdateRec]) -> int:
        resume = min([buf[0].lsn for buf in self.pending.values()]
                     + [commit_lsn + 1])
        txn = self.db.tc.begin()
        try:
            for rec in ops:
                self.db.tc.apply_shipped(txn, rec)
                self.db.note_update()        # replica-local Delta-records
            self.db.tc.update(txn, REPL_TABLE, REPL_KEY,
                              pack_watermark(commit_lsn, resume))
        except Exception:
            # keep the replica committed-only consistent: logically undo the
            # partially applied prefix (before-images are on the local log),
            # put the buffer back, and surface the failure — e.g. a record
            # that fits the primary's page size but not this geometry
            self.db.tc.abort(txn)
            self.pending[src_txn] = ops
            raise
        self.db.tc.commit(txn)
        self.db.post_commit_flush()
        self.applied_lsn, self.resume_lsn = commit_lsn, resume
        self.applied_txns += 1
        self.applied_ops += len(ops)
        return len(ops)

    # ------------------------------------------------------------- lag / reads
    def lag(self, primary_log) -> int:
        """Staleness in primary-LSN units: distance from the primary's last
        *stable commit* (non-commit tail records — in-flight work, abort
        trails — cannot make a committed-only replica stale)."""
        lc = min(primary_log.last_commit_lsn, primary_log.stable_lsn)
        return max(0, lc - self.applied_lsn)

    def read(self, table: str, key: bytes) -> Optional[bytes]:
        return self.db.dc.read(table, key)

    def user_state(self) -> dict[bytes, bytes]:
        """Replica state minus the ``__repl`` system table — directly
        comparable against ``committed_state_oracle``."""
        return {k: v for k, v in self.db.scan_all()
                if split_key(k)[0] != REPL_TABLE}

    # ------------------------------------------------------- crash / recovery
    def crash(self) -> CrashImage:
        return self.db.crash()

    def recover_local(self, strategy: Strategy = Strategy.LOG1,
                      image: Optional[CrashImage] = None) -> RecoveryStats:
        """Crash (or take ``image``) and recover THIS replica with the
        paper's own machinery, on its own geometry, from its own
        Delta-records — then restore the replication position from the
        durable watermark row.  In-flight buffers are volatile and lost; the
        ``resume`` watermark is exactly what makes that safe."""
        image = image or self.db.crash()
        self.db, stats = recover(image, strategy,
                                 cache_pages=self.cache_pages,
                                 delta_mode=self.delta_mode,
                                 page_size=self.page_size,
                                 tracker_interval=self.tracker_interval,
                                 bg_flush_per_txn=self.bg_flush_per_txn)
        self.pending = {}
        raw = self.db.dc.read(REPL_TABLE, REPL_KEY)
        self.applied_lsn, self.resume_lsn = \
            unpack_watermark(raw) if raw is not None else (NULL_LSN, 1)
        self._ship_pos = self.resume_lsn
        return stats

    def resubscribe(self, shipper: LogShipper) -> None:
        """Point ``shipper`` at this replica's durable resume point.  Also
        rewinds the in-flight buffers: everything from ``resume_lsn`` on is
        about to be re-shipped, and keeping stale buffers would double-apply
        straddling transactions."""
        self.pending = {}
        self._ship_pos = self.resume_lsn
        shipper.subscribe(self.replica_id, self.resume_lsn)
