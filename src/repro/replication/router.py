"""Read routing over a primary + N hot standbys.

Consistency contract: every read carries an optional staleness bound —

  ``min_lsn``  a read-your-writes token (the commit LSN returned by
               ``write``): only replicas whose ``applied_lsn`` has reached
               the token may serve, because commits apply in primary-LSN
               order, so ``applied_lsn >= t`` implies every commit <= t is
               visible.
  ``max_lag``  an absolute bound in primary-LSN units on how far behind the
               serving replica may be.

A read no replica can serve within its bound falls back to the primary,
which is always current.  Eligible replicas are balanced round-robin.
Ranged scans follow the same contract with one extra rule: over a sharded
standby the eligibility watermark is the *min* across the shards the range
spans (``watermark_for_range``), and that min is returned as the per-scan
staleness token.

Re-seeding: with a ``SnapshotStore`` attached, a subscriber that falls
below the log's retention horizon (``SnapshotRequired`` from the shipper)
is automatically re-seeded from the newest snapshot — taking a fresh one
if none covers the retained log — and re-subscribed at its ``redo_lsn``.

Failover: ``promote`` drains and promotes the most caught-up replica (see
``failover.promote``) and re-points the set's shipper at the new primary's
log.  The remaining replicas hold watermarks in the *old* primary's LSN
space, which does not map onto the new log; with a ``SnapshotStore``
attached they are re-seeded from a fresh snapshot of the new primary and
re-subscribed — no survivor is left permanently detached.  Without one,
the pre-archive behavior remains: survivors detach and wait for a manual
re-seed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..archive import SnapshotRequired, SnapshotStore
from ..core.records import LSN, NULL_LSN
from ..core.tc import CrashImage, Database
from .failover import promote
from .replica import Replica
from .shipper import LogShipper


@dataclass
class ReadResult:
    value: Optional[bytes]
    source: str                 # replica id, or "primary"
    applied_lsn: LSN            # position the serving node had reached


@dataclass
class RangeReadResult:
    """A routed ranged scan.  ``watermark`` is the per-scan staleness
    token: every commit <= watermark touching the range is reflected (a
    sharded server may additionally show newer work on its faster shards,
    same as its point reads between epoch barriers)."""
    rows: list
    source: str
    watermark: LSN


class ReplicaSet:
    def __init__(self, primary: Database, replicas: list[Replica] = (),
                 *, batch_records: int = 256, auto_sync: bool = False,
                 snapshots: Optional[SnapshotStore] = None):
        self.primary = primary
        self.shipper = LogShipper(primary.log, batch_records=batch_records)
        self.snapshots = snapshots
        self.reseeds = 0
        self.replicas: dict[str, Replica] = {}
        for r in replicas:
            self.add_replica(r)
        self._rr = 0
        self.reads_primary = 0
        self.reads_replica = 0
        self.auto_sync = auto_sync
        if auto_sync:
            # commit hook: pump shipping as soon as new records are stable
            primary.tc.on_commit.append(lambda _txn, _lsn: self.sync())

    def add_replica(self, replica: Replica) -> None:
        self.replicas[replica.replica_id] = replica
        try:
            replica.resubscribe(self.shipper)
        except SnapshotRequired:
            if self.snapshots is None:
                raise
            self._reseed(replica)

    def _reseed(self, replica: Replica) -> None:
        """Re-seed one standby from the newest snapshot and re-subscribe it
        at the snapshot's redo point.  A snapshot whose redo range was
        already pruned can't be caught up from — take a fresh one."""
        snap = self.snapshots.latest()
        if snap is None or snap.redo_lsn < self.primary.log.retained_lsn:
            snap = self.snapshots.take(self.primary)
        replica.reseed_from(snap)
        self.shipper.subscribe(replica.replica_id, replica.resume_lsn)
        self.reseeds += 1

    # -------------------------------------------------------------- traffic
    def write(self, ops) -> LSN:
        """Run a transaction on the primary; the returned commit LSN is the
        read-your-writes token for subsequent routed reads."""
        return self.primary.run_txn(ops)

    def sync(self, max_records: Optional[int] = None) -> int:
        """Pump shipping: one bounded poll per replica (or full drain when
        ``max_records`` is None).  Returns ops applied across the set.
        Detached replicas (no shipping cursor — e.g. unsubscribed pending a
        re-seed) are skipped cleanly; they can still serve bounded reads
        from whatever they last applied.  A subscriber whose cursor fell
        below the retention horizon is re-seeded in place when a
        ``SnapshotStore`` is attached."""
        applied = 0
        for r in self.replicas.values():
            if not self.shipper.is_subscribed(r.replica_id):
                continue
            try:
                if max_records is None:
                    before = r.applied_ops
                    self.shipper.drain(r.replica_id, r.apply_batch)
                    applied += r.applied_ops - before
                else:
                    applied += r.apply_batch(
                        self.shipper.poll(r.replica_id, max_records))
            except SnapshotRequired:
                if self.snapshots is None:
                    raise
                self._reseed(r)
                # retry under the caller's pacing contract: a full drain
                # only when one was asked for, one bounded poll otherwise
                if max_records is None:
                    before = r.applied_ops
                    self.shipper.drain(r.replica_id, r.apply_batch)
                    applied += r.applied_ops - before
                else:
                    applied += r.apply_batch(
                        self.shipper.poll(r.replica_id, max_records))
        return applied

    def read(self, table: str, key: bytes, *, min_lsn: LSN = NULL_LSN,
             max_lag: Optional[int] = None) -> ReadResult:
        reps = list(self.replicas.values())
        for i in range(len(reps)):
            r = reps[(self._rr + i) % len(reps)]
            # per-key watermark: the serial path answers with its global
            # applied watermark, the sharded path with the serving key
            # range's volatile watermark (commits applied per shard in
            # primary-LSN order, so shard watermark >= t implies every
            # commit <= t touching this key is visible)
            wm = r.watermark_for(table, key)
            if wm < min_lsn:
                continue
            if max_lag is not None and r.lag(self.primary.log) > max_lag:
                continue
            self._rr = (self._rr + i + 1) % max(len(reps), 1)
            self.reads_replica += 1
            return ReadResult(r.read(table, key), r.replica_id, wm)
        self.reads_primary += 1
        # committed_read, not dc.read: the fallback must honor the same
        # committed-only visibility the replica path enforces — and the
        # token it hands back is the last *stable* commit, the newest
        # position a committed-only consumer can ever be asked to reach
        return ReadResult(self.primary.tc.committed_read(table, key),
                          "primary", self.primary.log.last_stable_commit_lsn)

    def read_range(self, table: str, lo: Optional[bytes] = None,
                   hi: Optional[bytes] = None, *, min_lsn: LSN = NULL_LSN,
                   max_lag: Optional[int] = None) -> RangeReadResult:
        """Routed ranged scan of ``table`` keys in [lo, hi) (None = table
        edge).  Eligibility uses ``watermark_for_range`` — over a sharded
        standby that is the min volatile watermark across the shards the
        range spans, so a token t is only served once *every* spanned shard
        has applied commit t, no matter how far ahead the others are.  The
        serving watermark comes back as the scan's staleness token."""
        reps = list(self.replicas.values())
        for i in range(len(reps)):
            r = reps[(self._rr + i) % len(reps)]
            wm = r.watermark_for_range(table, lo, hi)
            if wm < min_lsn:
                continue
            if max_lag is not None and r.lag(self.primary.log) > max_lag:
                continue
            self._rr = (self._rr + i + 1) % max(len(reps), 1)
            self.reads_replica += 1
            return RangeReadResult(r.scan_range(table, lo, hi),
                                   r.replica_id, wm)
        self.reads_primary += 1
        # same committed-only visibility as the point-read fallback
        return RangeReadResult(
            self.primary.tc.committed_scan_range(table, lo, hi),
            "primary", self.primary.log.last_stable_commit_lsn)

    # -------------------------------------------------------------- failover
    def max_lag(self) -> int:
        return max((r.lag(self.primary.log) for r in self.replicas.values()),
                   default=0)

    def promote(self, replica_id: Optional[str] = None,
                image: Optional[CrashImage] = None) -> Database:
        """Fail over to ``replica_id`` (default: the most caught-up
        replica).  ``image``: the dead primary's crash image; when given,
        the drain reads the stable log that survived the crash rather than
        the live primary's."""
        if not self.replicas:
            raise RuntimeError("no replicas to promote (a prior failover "
                               "without a SnapshotStore detaches survivors; "
                               "re-seed standbys first)")
        if replica_id is None:
            # catchup_lsn, not applied_lsn: a sharded standby mid-epoch has
            # applied past its durable barrier, and that work counts
            replica_id = max(self.replicas,
                             key=lambda rid: self.replicas[rid].catchup_lsn())
        chosen = self.replicas.pop(replica_id)
        shipper = self.shipper if image is None \
            else self._shipper_for_image(image, chosen)
        # (Re-)attach the drain at the exact position the replica consumed
        # through, unconditionally: a detached standby has no cursor at all,
        # and a live cursor can sit AHEAD of _ship_pos when a poll's apply
        # failed mid-batch — draining from either would trip the gap guard
        # after the replica was already popped from the set.  Re-delivery
        # below _ship_pos is skipped, so rewinding is always safe.
        shipper.subscribe(chosen.replica_id, chosen._ship_pos)
        new_primary = promote(chosen, shipper)
        survivors = self.replicas
        self.primary = new_primary
        self.shipper = LogShipper(new_primary.log,
                                  batch_records=self.shipper.batch_records)
        self.replicas = {}
        if self.snapshots is not None:
            # snapshots are positions in one LSN space; the old store dies
            # with the old primary and a fresh one serves the new log
            self.snapshots = SnapshotStore(
                exclude_tables=tuple(self.snapshots.exclude_tables))
            if survivors:
                # one fresh snapshot of the new primary re-seeds every
                # survivor: same rows, each keeps its own geometry
                snap = self.snapshots.take(new_primary)
                for r in survivors.values():
                    r.reseed_from(snap)
                    self.replicas[r.replica_id] = r
                    self.shipper.subscribe(r.replica_id, r.resume_lsn)
        # without a SnapshotStore, survivors hold old-LSN-space watermarks
        # that do not map onto the new log and stay detached (module doc)
        if self.auto_sync:          # the contract survives the failover
            new_primary.tc.on_commit.append(lambda _txn, _lsn: self.sync())
        return new_primary

    def _shipper_for_image(self, image: CrashImage,
                           replica: Replica) -> LogShipper:
        s = LogShipper(image.log, batch_records=self.shipper.batch_records)
        # _ship_pos, not the live cursor: a poll whose apply failed leaves
        # the cursor ahead of what the replica consumed, and the drain must
        # restart from the consumed position (re-delivery below it is
        # skipped, a gap above it would abort the promotion)
        s.subscribe(replica.replica_id, replica._ship_pos)
        return s
