"""Log shipping: incremental, cursor-based streaming of the primary's stable
logical log.

The paper's PID-free log is what makes this subsystem possible at all
(Section 1.1): the records crossing the wire carry only logical identity
(table, key, before, after), so the consumer may have any physical layout —
different page size, different B-tree shape, its own Delta-records.  This is
the "unbundled" Deuteronomy deployment: one TC log, many DCs.

Only the *stable* prefix ships.  A replica must never apply work its primary
could still disown in a crash, so the shipper reads through
``LogManager.scan_stable`` and never sees the unforced tail.

Cursors are soft state.  A shipper that restarts (or a brand-new shipper
pointed at the same log) resumes from the consumer's durable resume point —
the replica persists (applied, resume) transactionally with the data it
applies, so no shipper-side durability is needed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Union

from ..archive.errors import SnapshotRequired
from ..core.log import LogManager, TruncatedLogError
from ..core.records import LSN, AbortRec, CommitRec, LogRec, UpdateRec
from ..obs import metrics as _metrics
from ..obs.flightrec import FLIGHT as _FLIGHT

_C_SHIPPED = _metrics.counter("ship.shipped_records")
_C_POLLS = _metrics.counter("ship.polls")

# What crosses the wire: the TC-logical records a committed-only consumer
# needs.  DC-private physical records (Delta, BW, SMO, RSSP) and checkpoint
# records describe the *primary's* geometry and recovery state; they are
# meaningless — and actively harmful — on a DC with its own layout.  CLRs
# are also omitted: a transaction either commits cleanly (no CLRs) or ends
# in an AbortRec, and the abort alone tells a buffering consumer to discard.
SHIPPED_KINDS = (UpdateRec, CommitRec, AbortRec)


@dataclass
class ShipBatch:
    """One poll's worth of shipped records.

    ``records`` keeps the primary's LSNs intact (replicas key their
    watermarks on primary LSNs); ``from_lsn``/``next_lsn`` delimit the LSN
    range this batch covers (consumers use them to detect gaps in the
    stream); ``has_more`` says whether more stable records were available
    beyond this batch at poll time."""
    records: List[LogRec] = field(default_factory=list)
    from_lsn: LSN = 1
    next_lsn: LSN = 1
    has_more: bool = False
    #: commit LSN -> primary flush stamp (perf_counter) for the CommitRecs
    #: in this batch — the t0 side of commit-to-visible.  Absent entries
    #: (stamp evicted, or a hand-built batch) just skip the histogram.
    stamps: dict = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.records)


class LogShipper:
    """Streams stable log records to named subscribers in bounded batches.

    ``source`` may be a live ``Database``, a ``CrashImage`` (failover: the
    primary is dead but its stable log survives), or a bare ``LogManager``.
    """

    def __init__(self, source: Union[LogManager, object],
                 batch_records: int = 256, retry=None):
        self.log: LogManager = source if isinstance(source, LogManager) \
            else source.log
        self.batch_records = batch_records
        # a ``faults.RetryPolicy``: when shipping reads through a spliced
        # archive (cold cursor), a transient backend outage under
        # scan_stable retries bounded instead of failing the poll.  The
        # cursor only advances after a successful scan, so a failed poll
        # re-ships nothing and loses nothing.
        self.retry = retry
        self.cursors: dict[str, LSN] = {}
        self.shipped_records = 0
        self.polls = 0

    # --------------------------------------------------------- subscriptions
    def subscribe(self, replica_id: str, from_lsn: LSN = 1) -> None:
        """(Re-)register a subscriber; ``from_lsn`` is typically the
        replica's durable resume point.

        A resume point below the log's retention horizon (records pruned
        from the archive, or truncated with no archive) can never be
        served — raising ``SnapshotRequired`` here, at subscribe time,
        beats handing out silent empty batches that would strand the
        subscriber forever."""
        from_lsn = max(from_lsn, 1)
        retained = getattr(self.log, "retained_lsn", 1)
        if from_lsn < retained:
            raise SnapshotRequired(replica_id, from_lsn, retained)
        self.cursors[replica_id] = from_lsn

    def unsubscribe(self, replica_id: str) -> None:
        self.cursors.pop(replica_id, None)

    def is_subscribed(self, replica_id: str) -> bool:
        return replica_id in self.cursors

    def _cursor(self, replica_id: str) -> LSN:
        try:
            return self.cursors[replica_id]
        except KeyError:
            raise KeyError(
                f"no shipping cursor for {replica_id!r}: the subscriber is "
                "detached (never subscribed, or unsubscribed) — call "
                "subscribe(replica_id, from_lsn) first, typically from the "
                "replica's durable resume_lsn") from None

    def backlog(self, replica_id: str) -> int:
        """Stable records not yet shipped to this subscriber."""
        return max(0, self.log.stable_lsn - (self._cursor(replica_id) - 1))

    def min_cursor(self) -> Optional[LSN]:
        """Slowest subscriber's position — the shipping half of the
        ``min(snapshot horizon, slowest subscriber)`` truncation watermark
        (``archive.Archiver``).  None when nobody subscribes."""
        return min(self.cursors.values(), default=None)

    # ---------------------------------------------------------------- polling
    def poll(self, replica_id: str,
             max_records: Optional[int] = None) -> ShipBatch:
        """Ship the next batch for ``replica_id`` and advance its cursor.

        Only logical (shippable) records count against the batch budget;
        filtered physical records are skipped over for free, so a bounded
        poll always makes logical progress when logical backlog exists —
        a checkpoint burst on the primary can't starve a small batch."""
        cur = self._cursor(replica_id)
        budget = max_records if max_records is not None else self.batch_records
        shipped: List[LogRec] = []
        nxt = cur
        done = False
        while not done:
            try:
                if self.retry is None:
                    chunk, _ = self.log.scan_stable(nxt, 64)
                else:
                    chunk, _ = self.retry.call(self.log.scan_stable, nxt, 64)
            except TruncatedLogError:
                # the cursor fell below the retention horizon (segments
                # pruned underneath a stalled subscriber): shipping cannot
                # resume from here, only a re-seed can
                raise SnapshotRequired(
                    replica_id, nxt,
                    getattr(self.log, "retained_lsn", 1)) from None
            if not chunk:
                break
            for rec in chunk:
                if isinstance(rec, SHIPPED_KINDS):
                    if len(shipped) >= budget:
                        done = True     # leave this record for the next poll
                        break
                    shipped.append(rec)
                nxt = rec.lsn + 1
        self.cursors[replica_id] = nxt
        self.shipped_records += len(shipped)
        self.polls += 1
        _C_SHIPPED.inc(len(shipped))
        _C_POLLS.inc()
        _FLIGHT.record("ship.poll", cur, len(shipped))
        _metrics.gauge("ship.backlog", replica=replica_id).set(
            max(0, self.log.stable_lsn - (nxt - 1)))
        # carry each shipped commit's flush stamp so the applier can
        # close the commit-to-visible loop (CrashImage sources keep the
        # stamps of their stable commits; bare test logs may have none)
        primary_stamps = getattr(self.log, "commit_stamps", None) or {}
        stamps = {}
        for rec in shipped:
            if isinstance(rec, CommitRec):
                t = primary_stamps.get(rec.lsn)
                if t is not None:
                    stamps[rec.lsn] = t
        return ShipBatch(records=shipped, from_lsn=cur, next_lsn=nxt,
                         has_more=nxt <= self.log.stable_lsn,
                         stamps=stamps)

    def drain(self, replica_id: str, apply) -> int:
        """Poll until no stable records remain, feeding each batch to
        ``apply``; returns the number of records shipped."""
        total = 0
        while True:
            batch = self.poll(replica_id)
            total += len(batch)
            apply(batch)
            if not batch.has_more:
                return total
