"""Bounded, deterministic retry with exponential backoff + seeded jitter.

The classification contract, in one place: the *only* exception class a
``RetryPolicy`` absorbs is ``BackendUnavailableError`` — the backend
failed, the bytes are presumed intact, trying again can help.  Corruption
(``CorruptSegmentError``/``UnknownFormatError``/``TruncatedLogError``/
``PageCorruptError``) propagates on the first throw: retrying re-reads
the same wrong bytes and, worse, a retry loop that "handles" corruption
converts data loss into silence.  reprolint's ``retry-discipline`` rule
pins exactly this shape on every catcher in the tree.

Determinism: backoff delays are a pure function of ``(seed, attempt)`` —
jitter comes from ``SplitMix64``, never the stdlib ``random`` (the
determinism lint rule covers this package).  By default no wall-clock
sleeping happens at all: delays are *charged* to ``slept_ms`` (and to an
iosim-style clock when one is attached), which keeps every test and the
torture sweep instant and replayable.  A deployment that wants real
sleeping passes ``sleep=time.sleep``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

from ..media.errors import BackendUnavailableError
from ..obs import metrics as _metrics
from ..obs.flightrec import FLIGHT as _FLIGHT
from .plan import SplitMix64

_C_RETRIES = _metrics.counter("retry.attempts")
_C_EXHAUSTED = _metrics.counter("retry.exhausted")


class RetryPolicy:
    """Bounded attempts, exponential backoff, seeded jitter.

    One policy instance is one backoff schedule: ``call`` runs a thunk
    through it, ``backoff(attempt)`` exposes the schedule to callers that
    own their own loop (``Replica.catch_up``, the buffer pool's eviction
    path) — both shapes satisfy ``retry-discipline`` because both are
    bounded by ``max_attempts`` and both touch only the transient branch
    of the error hierarchy.
    """

    def __init__(self, max_attempts: int = 4, base_delay_ms: float = 1.0,
                 multiplier: float = 2.0, max_delay_ms: float = 250.0,
                 jitter_frac: float = 0.25, seed: int = 0,
                 sleep: Optional[Callable[[float], None]] = None,
                 clock: Optional[object] = None) -> None:
        if max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        self.max_attempts = max_attempts
        self.base_delay_ms = base_delay_ms
        self.multiplier = multiplier
        self.max_delay_ms = max_delay_ms
        self.jitter_frac = jitter_frac
        self.seed = seed
        self.sleep = sleep               # real sleeping is opt-in
        self.clock = clock               # iosim-style: .work(ms)
        self._rng = SplitMix64(seed)
        # stats (instance-level; process-wide mirrors via the registry)
        self.retries = 0
        self.exhausted = 0
        self.slept_ms = 0.0

    # ------------------------------------------------------------- schedule
    def delay_ms(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (1-based): exponential
        with +/- ``jitter_frac`` seeded jitter, capped at
        ``max_delay_ms``.  Consumes PRNG state — deterministic across a
        policy's lifetime, not per call."""
        base = min(self.base_delay_ms * (self.multiplier ** (attempt - 1)),
                   self.max_delay_ms)
        jitter = 1.0 + self.jitter_frac * (2.0 * self._rng.uniform() - 1.0)
        return base * jitter

    def backoff(self, attempt: int) -> float:
        """Charge (and optionally sleep) one backoff step; returns the
        delay in ms.  The deterministic clock, when attached, advances by
        the same amount — injected latency and retry delay share one
        timeline."""
        delay = self.delay_ms(attempt)
        self.slept_ms += delay
        self.retries += 1
        _C_RETRIES.inc()
        _FLIGHT.record("retry.backoff", attempt, delay)
        work = getattr(self.clock, "work", None)
        if work is not None:
            work(delay)
        if self.sleep is not None:
            self.sleep(delay / 1e3)
        return delay

    # ----------------------------------------------------------------- call
    def call(self, fn: Callable[..., Any], *args: Any, **kwargs: Any) -> Any:
        """Run ``fn`` with bounded retries on transient backend failure.

        Only ``BackendUnavailableError`` is ever absorbed; everything
        else — corruption first among it — propagates on the first
        throw.  After ``max_attempts`` tries the last transient error
        propagates too: a retry policy bounds an outage, it does not
        hide one."""
        attempt = 1
        while True:
            try:
                return fn(*args, **kwargs)
            except BackendUnavailableError:
                if attempt >= self.max_attempts:
                    self.exhausted += 1
                    _C_EXHAUSTED.inc()
                    raise
                self.backoff(attempt)
                attempt += 1
