"""Deterministic fault plans: *which* backend operation fails, *how*.

A ``FaultPlan`` is the whole description of a fault campaign — a tuple of
``FaultSpec``s plus a seed — and it fully determines the injected
sequence: the same plan driven over the same operation stream injects
byte-identical faults, run after run.  That property is what makes a
crash-point sweep (``tools/torture``) a *test* rather than a fuzz: every
red result replays exactly.

No stdlib ``random`` anywhere (reprolint's determinism rule covers this
package like the rest of the engine): the only randomness is
``SplitMix64``, a tiny seeded generator used for plan generation and for
``RetryPolicy`` jitter, both pure functions of their seed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, List, Optional, Tuple

#: fault kinds a spec may name
KIND_UNAVAILABLE = "unavailable"    # raise BackendUnavailableError
KIND_LATENCY = "latency"            # charge injected clock, then proceed
KIND_TORN_CRASH = "torn_crash"      # persist a truncated prefix, then crash
KIND_CRASH = "crash"                # crash before the op takes effect
KIND_LOST = "lost"                  # the blob is permanently gone
ALL_KINDS = (KIND_UNAVAILABLE, KIND_LATENCY, KIND_TORN_CRASH, KIND_CRASH,
             KIND_LOST)

#: numeric codes for flight-recorder probes (compact positional args only)
KIND_CODE = {k: i for i, k in enumerate(ALL_KINDS, start=1)}


class InjectedCrash(BaseException):
    """The simulated process death of a torn-write / crash fault.

    Deliberately a ``BaseException``: no ``except Exception`` cleanup
    handler anywhere in the stack may absorb a crash — the torture driver
    is the only legitimate catcher, and what it does next (recover from
    the crash image, cold-restore from the backend) is the point of the
    exercise."""

    def __init__(self, op: str, name: str, op_index: int) -> None:
        self.op = op
        self.name = name
        self.op_index = op_index
        super().__init__(
            f"injected crash at backend op #{op_index} ({op} {name!r})")


class SplitMix64:
    """Tiny deterministic PRNG (splitmix64): one u64 of state, full
    period, good enough for jitter and plan generation — and, unlike the
    stdlib, explicit about its seed."""

    MASK = (1 << 64) - 1

    def __init__(self, seed: int) -> None:
        self._state = seed & self.MASK

    def next_u64(self) -> int:
        self._state = (self._state + 0x9E3779B97F4A7C15) & self.MASK
        z = self._state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return z ^ (z >> 31)

    def uniform(self) -> float:
        """[0, 1) with 53 random bits."""
        return (self.next_u64() >> 11) / float(1 << 53)

    def randint(self, lo: int, hi: int) -> int:
        """Inclusive [lo, hi]."""
        return lo + self.next_u64() % (hi - lo + 1)

    def choice(self, seq):
        return seq[self.next_u64() % len(seq)]


@dataclass(frozen=True)
class FaultSpec:
    """One fault: fire on the ``at``-th matching call (1-based, counted
    per spec over ops matching ``op``/``name_prefix``), for ``count``
    consecutive matching calls."""
    op: str                      # "put" | "get" | "get_head" | "delete" |
    #                              "list" | "*"
    kind: str                    # one of ALL_KINDS
    at: int                      # 1-based index among matching calls
    count: int = 1               # consecutive matching calls affected
    name_prefix: str = ""        # restrict to blob names with this prefix
    latency_ms: float = 0.0      # KIND_LATENCY charge per hit
    torn_frac: float = 0.5       # KIND_TORN_CRASH: prefix fraction persisted

    def __post_init__(self) -> None:
        if self.kind not in ALL_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {ALL_KINDS})")
        if self.at < 1 or self.count < 1:
            raise ValueError("FaultSpec.at and .count are 1-based and >= 1")


@dataclass
class FaultPlan:
    """A seeded, fully deterministic fault campaign.

    ``match(op, name)`` is called by ``FaultyBackend`` once per backend
    operation and returns the spec to inject now (or None).  The plan
    keeps the campaign's bookkeeping: per-spec hit counts, the global op
    counter, and the injected trace — ``(op_index, op, kind, name)``
    tuples — which the seed-determinism property asserts on.

    After a crash-kind fault fires the plan disarms itself: the "process"
    died, and the recovery that follows must run against a quiet backend.
    """
    faults: Tuple[FaultSpec, ...] = ()
    seed: int = 0

    total_ops: int = field(default=0, init=False)
    crashed: bool = field(default=False, init=False)
    injected: List[Tuple[int, str, str, str]] = field(default_factory=list,
                                                      init=False)

    def __post_init__(self) -> None:
        self.faults = tuple(self.faults)
        self._seen: List[int] = [0] * len(self.faults)

    # ------------------------------------------------------------- matching
    def match(self, op: str, name: str) -> Optional[FaultSpec]:
        """Advance the op stream by one ``op`` on ``name``; return the
        spec to inject for this operation, or None.  The first armed spec
        in declaration order wins (plans wanting overlap compose them
        explicitly)."""
        self.total_ops += 1
        if self.crashed:
            return None
        hit: Optional[FaultSpec] = None
        for i, spec in enumerate(self.faults):
            if spec.op != "*" and spec.op != op:
                continue
            if spec.name_prefix and not name.startswith(spec.name_prefix):
                continue
            self._seen[i] += 1
            if hit is None and \
                    spec.at <= self._seen[i] < spec.at + spec.count:
                hit = spec
        if hit is not None:
            self.injected.append((self.total_ops, op, hit.kind, name))
            if hit.kind in (KIND_TORN_CRASH, KIND_CRASH):
                self.crashed = True
        return hit

    def disarm(self) -> None:
        """Stop injecting (the recovery half of a torture run)."""
        self.crashed = True

    # ----------------------------------------------------------- generation
    @classmethod
    def generate(cls, seed: int, n_faults: int = 4,
                 ops: Iterable[str] = ("put", "get", "delete"),
                 kinds: Iterable[str] = (KIND_UNAVAILABLE, KIND_LATENCY),
                 window: int = 200) -> "FaultPlan":
        """A deterministic pseudo-random campaign: ``n_faults`` specs over
        the first ``window`` matching calls, entirely a function of
        ``seed``.  Crash kinds are excluded by default — a generated soak
        plan should perturb, not kill, unless asked."""
        rng = SplitMix64(seed)
        ops_t, kinds_t = tuple(ops), tuple(kinds)
        specs = []
        for _ in range(n_faults):
            kind = rng.choice(kinds_t)
            specs.append(FaultSpec(
                op=rng.choice(ops_t), kind=kind,
                at=rng.randint(1, max(1, window)),
                count=rng.randint(1, 3) if kind == KIND_UNAVAILABLE else 1,
                latency_ms=round(rng.uniform() * 5.0, 3)
                if kind == KIND_LATENCY else 0.0))
        return cls(faults=tuple(specs), seed=seed)
