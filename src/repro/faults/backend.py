"""``FaultyBackend``: any ``MediaBackend``, plus a deterministic adversary.

Wraps an inner backend and consults a ``FaultPlan`` on every operation.
Between faults it is a pure pass-through (the disabled-hook cost is
CI-bounded at <=5% of batched Log1 redo — ``benchmarks/faults_bench``),
so the same wrapped backend serves both the torture sweep and ordinary
tests.  Fault kinds:

  unavailable   raise ``BackendUnavailableError`` — the transient outage
                every retry path in the stack must absorb (bounded).
  latency       charge ``latency_ms`` to the iosim-style clock (or the
                wrapper's own ``injected_latency_ms`` tally), then serve.
  torn_crash    ``put`` persists a *truncated prefix* of the blob to the
                inner backend, then raises ``InjectedCrash`` — the
                non-atomic cloud write the DirectoryBackend's
                temp+rename discipline exists to prevent.  Whoever later
                decodes the torn blob must go loud (CRC), never short.
  crash         raise ``InjectedCrash`` before the operation takes any
                effect — clean process death at an exact backend op.
  lost          the blob is permanently gone: deleted from the inner
                backend and pinned missing, so every later read answers
                ``BackendMissingError`` (a definite absence, not an
                outage — retrying is wrong and nothing retries it).

Every injection counts ``faults.injected{op,kind}`` and leaves a flight-
recorder breadcrumb, so a post-mortem of a torture failure shows the
exact op index that was hit.
"""
from __future__ import annotations

from typing import Optional

from ..media.backend import MediaBackend
from ..media.errors import BackendMissingError, BackendUnavailableError
from ..obs import metrics as _metrics
from ..obs.flightrec import FLIGHT as _FLIGHT
from .plan import (KIND_CODE, KIND_CRASH, KIND_LATENCY, KIND_LOST,
                   KIND_TORN_CRASH, KIND_UNAVAILABLE, FaultPlan, FaultSpec,
                   InjectedCrash)


class FaultyBackend(MediaBackend):
    """A ``MediaBackend`` whose failures are scripted by a ``FaultPlan``.

    ``clock`` is anything with ``work(ms)`` (``core.storage.IOSim``); when
    absent, injected latency accumulates on ``injected_latency_ms`` so
    tests can still assert the charge."""

    def __init__(self, inner: MediaBackend,
                 plan: Optional[FaultPlan] = None,
                 clock: Optional[object] = None) -> None:
        self.inner = inner
        self.plan = plan if plan is not None else FaultPlan()
        self.clock = clock
        self.injected_latency_ms = 0.0
        self.lost: set[str] = set()
        self.injected_faults = 0

    # ------------------------------------------------------------ injection
    def _inject(self, op: str, name: str,
                data: Optional[bytes] = None) -> Optional[FaultSpec]:
        """Consult the plan for ``op`` on ``name`` and act on the spec.
        Raises for unavailable/crash kinds; returns the spec (for the
        caller's kind-specific follow-up) after charging latency or
        executing a loss."""
        spec = self.plan.match(op, name)
        if name in self.lost and op in ("get", "get_head"):
            raise BackendMissingError(name, "FaultyBackend(lost)")
        if spec is None:
            return None
        self.injected_faults += 1
        _metrics.counter("faults.injected", op=op, kind=spec.kind).inc()
        _FLIGHT.record("fault.inject", self.plan.total_ops,
                       KIND_CODE[spec.kind])
        if spec.kind == KIND_UNAVAILABLE:
            raise BackendUnavailableError(
                f"injected outage at backend op #{self.plan.total_ops} "
                f"({op} {name!r})")
        if spec.kind == KIND_LATENCY:
            self._charge(spec.latency_ms)
            return spec
        if spec.kind == KIND_CRASH:
            raise InjectedCrash(op, name, self.plan.total_ops)
        if spec.kind == KIND_TORN_CRASH:
            if op == "put" and data is not None:
                torn = data[: max(0, int(len(data) * spec.torn_frac))]
                self.inner.put(name, torn)    # the non-atomic half-write
            raise InjectedCrash(op, name, self.plan.total_ops)
        if spec.kind == KIND_LOST:
            self.lost.add(name)
            self.inner.delete(name)
            if op in ("get", "get_head"):
                raise BackendMissingError(name, "FaultyBackend(lost)")
        return spec

    def _charge(self, ms: float) -> None:
        self.injected_latency_ms += ms
        work = getattr(self.clock, "work", None)
        if work is not None:
            work(ms)

    # ------------------------------------------------------------ interface
    def put(self, name: str, data: bytes) -> None:
        spec = self._inject("put", name, data)
        if spec is not None and spec.kind == KIND_LOST:
            return                        # the write itself is what was lost
        if name in self.lost:
            self.lost.discard(name)       # a fresh write resurrects the name
        self.inner.put(name, data)

    def get(self, name: str) -> bytes:
        self._inject("get", name)
        return self.inner.get(name)

    def get_head(self, name: str, n: int) -> bytes:
        self._inject("get_head", name)
        return self.inner.get_head(name, n)

    def delete(self, name: str) -> None:
        self._inject("delete", name)
        self.inner.delete(name)

    def list(self, prefix: str = "") -> list[str]:
        self._inject("list", prefix)
        return self.inner.list(prefix)


def make_faulty(inner: MediaBackend, *specs: FaultSpec,
                seed: int = 0, clock: Optional[object] = None
                ) -> FaultyBackend:
    """Convenience: wrap ``inner`` with an explicit spec list."""
    return FaultyBackend(inner, FaultPlan(faults=tuple(specs), seed=seed),
                         clock=clock)
