"""Deterministic fault injection and the retry discipline built on it.

Sits directly above ``media``: a ``FaultyBackend`` wraps any
``MediaBackend`` and injects scripted failures — transient outages,
latency, torn writes, clean crashes, permanent blob loss — driven by a
seeded ``FaultPlan`` whose injected sequence is a pure function of the
plan.  ``RetryPolicy`` is the other half of the contract: the one
mediator through which the stack absorbs ``BackendUnavailableError``
(bounded attempts, deterministic backoff, seeded jitter), and through
which it must *never* absorb corruption.

The crash-point torture driver (``tools/torture.py``) composes the two:
enumerate every injectable point in a scripted workload, crash at each,
recover, and assert oracle-equality against the committed prefix.
"""
from .backend import FaultyBackend, make_faulty
from .plan import (ALL_KINDS, KIND_CODE, KIND_CRASH, KIND_LATENCY, KIND_LOST,
                   KIND_TORN_CRASH, KIND_UNAVAILABLE, FaultPlan, FaultSpec,
                   InjectedCrash, SplitMix64)
from .retry import RetryPolicy

__all__ = [
    "FaultPlan", "FaultSpec", "FaultyBackend", "make_faulty",
    "RetryPolicy", "InjectedCrash", "SplitMix64",
    "ALL_KINDS", "KIND_CODE", "KIND_UNAVAILABLE", "KIND_LATENCY",
    "KIND_TORN_CRASH", "KIND_CRASH", "KIND_LOST",
]
